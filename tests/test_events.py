"""Discrete-event engine: tick-oracle equivalence, batched dispatch,
time-based retirement, and the modeled SimServer backend.

Load-bearing invariants:
* ``engine="event"`` reproduces ``engine="tick"`` EXACTLY — identical
  per-request token streams, first-token/finish stamps (equal to the
  clock's float-accumulation epsilon), cold-start records, and
  GPU-seconds — while processing strictly fewer dense ticks
  whenever the trace has quiescent gaps.  The event engine only ever
  jumps time it can prove no tick would have used.
* ``select_many`` (one batched pass with virtual load accounting) makes
  the SAME picks as the repeated single-``select`` loop it replaced, for
  every shipped policy.
* Idle retirement is time-based: the same config retires after the same
  *seconds* under a ``LogicalClock`` and a ``WallClock`` (tick counts
  used to mean milliseconds of real time under wall clocks).
"""
import os
import time

import jax
import numpy as np
import pytest

from repro.cluster import (AdapterAffine, Arrival, Autoscaler,
                           AutoscalerConfig, ClusterConfig, ClusterRouter,
                           ClusterServer, LeastLoaded, LogicalClock,
                           SimProfile, SloAware, WallClock, arrival_stream,
                           burst_wave_trace, load_azure_trace, poisson_trace,
                           sim_server_factory)
from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import ServeRequest

KEY = jax.random.PRNGKey(3)
FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    return cfg, params


# ---------------------------------------------------------------------------
# event == tick equivalence (real servers)
# ---------------------------------------------------------------------------

def _run(cfg, params, trace, engine, **kw):
    router = ClusterRouter(cfg, params, n_servers=2,
                           ccfg=ClusterConfig(n_devices=2, n_slots=4), **kw)
    done = router.run(list(trace), engine=engine)
    return router, done


def _ts_eq(a, b):
    """Timestamp equality to float-accumulation noise: the tick engine
    sums ``t += tick_s`` once per tick while the event engine computes a
    jump target in one multiply — same grid point, ~1e-14 apart."""
    if a is None or b is None:
        return a is b
    return a == pytest.approx(b, abs=1e-9)


def _assert_equivalent(r_evt, done_evt, r_tick, done_tick):
    """The full equivalence contract: streams, stamps, cold starts,
    GPU-seconds."""
    evt = {r.rid: tuple(r.generated) for r in done_evt}
    tick = {r.rid: tuple(r.generated) for r in done_tick}
    assert evt == tick                                   # token streams
    assert set(r_evt.metrics.records) == set(r_tick.metrics.records)
    for rid, rt in r_tick.metrics.records.items():       # TTFT/finish stamps
        re_ = r_evt.metrics.records[rid]
        assert (re_.n_tokens, re_.server) == (rt.n_tokens, rt.server), rid
        assert _ts_eq(re_.first_token, rt.first_token), rid
        assert _ts_eq(re_.finished, rt.finished), rid
    # cold-start accounting on the ROUTER clock must match; the wall-clock
    # fields are real elapsed time and legitimately differ between runs
    cs_e, cs_t = r_evt.metrics.coldstart, r_tick.metrics.coldstart
    assert set(cs_e) == set(cs_t)
    for sid in cs_e:
        for k in ("served_while_loading", "loaded_bytes", "n_rounds"):
            assert cs_e[sid][k] == cs_t[sid][k], (sid, k)
        for k in ("time_to_ready", "time_to_fully_loaded"):
            assert _ts_eq(cs_e[sid][k], cs_t[sid][k]), (sid, k)
    assert r_evt.metrics.gpu_seconds == \
        pytest.approx(r_tick.metrics.gpu_seconds, rel=1e-9, abs=1e-9)


def test_event_equals_tick_poisson_with_gap(setup):
    """A burst, a long quiet gap, a straggler: the event engine must jump
    the gap (fewer dense ticks) yet reproduce the tick oracle exactly."""
    cfg, params = setup
    # straggler deliberately OFF the tick grid: the two engines' clocks
    # drift apart by ~1e-14 over hundreds of ticks, so an arrival exactly
    # on a grid point can land a tick apart — real traces never are
    trace = poisson_trace(5.0, 0.6, seed=5, max_new_tokens=3) \
        + [Arrival(3.013, max_new_tokens=3, seed=9)]
    r_evt, done_evt = _run(cfg, params, trace, "event")
    r_tick, done_tick = _run(cfg, params, trace, "tick")
    assert len(done_evt) == len(trace)
    _assert_equivalent(r_evt, done_evt, r_tick, done_tick)
    # on_tick fires once per DENSE tick: the jump must be visible
    assert len(r_evt.metrics.queue_depth) < len(r_tick.metrics.queue_depth)


def test_event_equals_tick_burst_wave(setup):
    cfg, params = setup
    trace = burst_wave_trace(8, base_rate=1.0, wave_rate=12.0, wave_at=0.5,
                             wave_len=0.5, seed=2, max_new_tokens=3)
    r_evt, done_evt = _run(cfg, params, trace, "event")
    r_tick, done_tick = _run(cfg, params, trace, "tick")
    assert len(done_evt) == len(trace)
    _assert_equivalent(r_evt, done_evt, r_tick, done_tick)


def test_event_equals_tick_azure_fixture(setup):
    cfg, params = setup
    trace = load_azure_trace(os.path.join(FIXTURES, "azure_sample.csv"),
                             minute_s=0.4, max_new_tokens=3,
                             max_requests=12, seed=0)
    r_evt, done_evt = _run(cfg, params, trace, "event")
    r_tick, done_tick = _run(cfg, params, trace, "tick")
    assert len(done_evt) == 12
    _assert_equivalent(r_evt, done_evt, r_tick, done_tick)


def test_run_rejects_unknown_engine():
    with pytest.raises(ValueError, match="unknown engine"):
        _sim_router().run([], engine="warp")


# ---------------------------------------------------------------------------
# event == tick equivalence (modeled backend, autoscaler, crash/rejoin)
# ---------------------------------------------------------------------------

def _sim_router(dispatch=None):
    return ClusterRouter(
        None, None, n_servers=2,
        ccfg=ClusterConfig(n_devices=1, n_slots=4),
        autoscaler=Autoscaler(AutoscalerConfig(
            target_queue_per_server=4.0, ttft_slo_s=0.4, max_servers=6,
            min_servers=1, scale_up_cooldown_ticks=3,
            idle_seconds_before_retire=1.0)),
        dispatch=dispatch or LeastLoaded(),
        server_factory=sim_server_factory(SimProfile(ready_ticks=2,
                                                     full_ticks=6)),
        materialize_prompts=False)


def _sim_trace():
    # two bursts separated by a gap long enough to retire scaled-up
    # servers, then a straggler that arrives at a shrunken fleet
    a = poisson_trace(40.0, 1.0, seed=7, max_new_tokens=4,
                      ttft_deadline_s=0.5)
    b = [Arrival(t.time + 6.0, max_new_tokens=4, seed=t.seed,
                 ttft_deadline_s=0.5)
         for t in poisson_trace(30.0, 0.8, seed=8)]
    return a + b + [Arrival(15.013, max_new_tokens=4, seed=1)]


def test_event_equals_tick_simserver_autoscaled():
    """Modeled backend under autoscaling: spawns, idle retires between
    bursts, and the straggler all replay identically on both engines."""
    trace = _sim_trace()
    routers, dones = {}, {}
    for eng in ("event", "tick"):
        r = _sim_router()
        dones[eng] = r.run(list(trace), engine=eng)
        routers[eng] = r
    assert len(dones["event"]) == len(trace)
    _assert_equivalent(routers["event"], dones["event"],
                       routers["tick"], dones["tick"])
    # the scale-up/retire event sequence matches too (times and kinds)
    evs = {e: [(t, k, d) for t, k, d in routers[e].metrics.events
               if k in ("spawn", "retire")] for e in routers}
    assert len(evs["event"]) == len(evs["tick"])
    for (te, ke, de), (tt, kt, dt) in zip(evs["event"], evs["tick"]):
        assert (ke, de) == (kt, dt)
        assert _ts_eq(te, tt)
    # the gap actually exercised retirement
    assert any(k == "retire" for _, k, _ in routers["event"].metrics.events)
    assert len(routers["event"].metrics.queue_depth) < \
        len(routers["tick"].metrics.queue_depth)


def test_event_equals_tick_crash_rejoin():
    """Crash + scheduled rejoin: the tick engine counts rejoin delay in
    loop iterations, the event engine schedules it in clock time — same
    ticks, same streams."""
    trace = _sim_trace()
    routers, dones = {}, {}
    for eng in ("event", "tick"):
        r = _sim_router()
        dones[eng] = r.run(list(trace), engine=eng,
                           crash_after_completions=10, crash_server_id=1,
                           rejoin_after_ticks=30)
        routers[eng] = r
    assert len(dones["event"]) == len(trace)
    _assert_equivalent(routers["event"], dones["event"],
                       routers["tick"], dones["tick"])
    for r in routers.values():
        kinds = [k for _, k, _ in r.metrics.events]
        assert "crash" in kinds and "rejoin" in kinds


def test_event_engine_consumes_streaming_iterator():
    """A generator trace (never a list) replays identically to the same
    arrivals passed as a list — the streaming contract of ``run``."""
    trace = sorted(_sim_trace(), key=lambda a: a.time)
    r_list = _sim_router()
    done_list = r_list.run(list(trace), engine="event")
    r_iter = _sim_router()
    done_iter = r_iter.run(iter(trace), engine="event")
    assert {r.rid: tuple(r.generated) for r in done_list} == \
        {r.rid: tuple(r.generated) for r in done_iter}
    _assert_equivalent(r_list, done_list, r_iter, done_iter)


def test_arrival_stream_sorts_lists_passes_iterators():
    tr = [Arrival(2.0), Arrival(0.5), Arrival(1.0)]
    assert [a.time for a in arrival_stream(tr)] == [0.5, 1.0, 2.0]
    gen = iter(tr)                        # assumed pre-sorted: passthrough
    assert arrival_stream(gen) is gen


def test_collect_finished_false_keeps_metrics_only():
    trace = _sim_trace()
    r = _sim_router()
    done = r.run(list(trace), engine="event", collect_finished=False)
    assert done == []
    assert r.metrics.summary()["n_completed"] == len(trace)


# ---------------------------------------------------------------------------
# select_many == repeated select (every shipped policy)
# ---------------------------------------------------------------------------

class _Batcher:
    def __init__(self, active, n_free):
        self.active = {r.rid: r for r in active}
        self.free = list(range(n_free))


class _Srv:
    """ServingEngine scheduling surface; ``submit`` mirrors the real
    engine so the repeated-select loop sees its own earlier picks."""

    def __init__(self, active=(), n_free=4, active_adapter=None,
                 adapter_params=(), queued=()):
        self.batcher = _Batcher(active, n_free)
        self.active_adapter = active_adapter
        self.adapter_params = {a: None for a in adapter_params}
        self._queued = list(queued)

    def resident_adapters(self):
        if self.batcher.active:
            return {self.active_adapter}
        return set(self.adapter_params) | {None, self.active_adapter}

    def predicted_step_cost_s(self, default=0.05):
        return default

    def queued_requests(self):
        return self._queued

    def submit(self, req):
        self._queued.append(req)


class _Server:
    def __init__(self, sid, state="serving", srv=None, ready_s=0.0):
        self.sid = sid
        self.state = state
        self.srv = srv or _Srv()
        self._ready_s = ready_s

    @property
    def admitting(self):
        return self.state == "serving"

    @property
    def load(self):
        return len(self.srv.batcher.active) + len(self.srv.queued_requests())

    def can_serve(self, req):
        return req.adapter is None or req.adapter in self.srv.adapter_params

    def predicted_ready_s(self, now):
        return 0.0 if self.state == "serving" else self._ready_s


def _req(rid, adapter=None, deadline=None, max_new=8, n_gen=0):
    r = ServeRequest(rid, np.zeros(4, np.int64), max_new_tokens=max_new,
                     adapter=adapter, deadline=deadline)
    r.generated = [0] * n_gen
    return r


def _scenario():
    """Mixed fleet + mixed queue: loads, adapters, deadlines, a warming
    server, a full server, an epoch-locked server."""
    servers = [
        _Server(0, srv=_Srv(active=[_req(90, "a", max_new=9, n_gen=2)],
                            n_free=3, active_adapter="a",
                            adapter_params=("a", "b"))),
        _Server(1, srv=_Srv(adapter_params=("a", "b"))),
        _Server(2, state="loading", ready_s=0.10,
                srv=_Srv(adapter_params=("a", "b"))),
        _Server(3, srv=_Srv(active=[_req(91, "b", max_new=12, n_gen=1)],
                            n_free=0, active_adapter="b",
                            adapter_params=("b",))),
    ]
    queue = [
        _req(0, adapter="b", deadline=0.9),
        _req(1, deadline=0.2),
        _req(2, adapter="a"),
        _req(3, adapter="a", deadline=0.2),
        _req(4, adapter="b"),
        _req(5),
        _req(6, adapter="c"),                 # unservable: skipped by all
        _req(7, deadline=0.5),
    ]
    return servers, queue


def _repeated_select(policy, servers, queue, now, ccfg):
    """The pre-refactor router loop: select one, pop it, submit it, ask
    again — returns picks as (original queue index, sid)."""
    remaining = list(enumerate(queue))
    picks = []
    while remaining:
        got = policy.select([r for _, r in remaining], servers, now, ccfg)
        if got is None:
            break
        idx, server = got
        orig, req = remaining.pop(idx)
        server.srv.submit(req)
        picks.append((orig, server.sid))
    return picks


@pytest.mark.parametrize("mk", [
    LeastLoaded,
    lambda: SloAware(step_cost_s=0.05),
    lambda: AdapterAffine(slo=SloAware(step_cost_s=0.05)),
], ids=["least_loaded", "slo_aware", "adapter_affine"])
def test_select_many_equals_repeated_select(mk):
    ccfg = ClusterConfig(n_slots=4)
    servers_a, queue_a = _scenario()
    batched = [(i, s.sid)
               for i, s in mk().select_many(queue_a, servers_a, 0.0, ccfg)]
    servers_b, queue_b = _scenario()
    looped = _repeated_select(mk(), servers_b, queue_b, 0.0, ccfg)
    assert batched == looped
    assert batched                            # scenario actually dispatches
    assert all(i != 6 for i, _ in batched)    # unservable never placed


def test_select_many_respects_virtual_capacity():
    """One empty 4-slot server, six requests: exactly four place — the
    batched pass must count its own picks against capacity."""
    ccfg = ClusterConfig(n_slots=4)
    servers = [_Server(0, srv=_Srv(adapter_params=("a",)))]
    queue = [_req(i) for i in range(6)]
    for mk in (LeastLoaded, lambda: SloAware(step_cost_s=0.05),
               lambda: AdapterAffine(slo=SloAware(step_cost_s=0.05))):
        picks = mk().select_many(queue, servers, 0.0, ccfg)
        assert [i for i, _ in picks] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# time-based idle retirement (both clocks)
# ---------------------------------------------------------------------------

class _IdleSrv:
    def __init__(self, sid, idle_since=None, idle_ticks=0, state="serving"):
        self.sid = sid
        self.state = state
        self.idle_since = idle_since
        self.idle_ticks = idle_ticks

    @property
    def admitting(self):
        return self.state == "serving"


def test_retire_fires_on_seconds_not_ticks():
    sc = Autoscaler(AutoscalerConfig(min_servers=1,
                                     idle_seconds_before_retire=2.0))
    servers = [_IdleSrv(0, idle_since=10.0), _IdleSrv(1, idle_since=11.5)]
    assert sc.decide(11.9, 0, 0.0, servers).retire == []
    assert sc.decide(12.0, 0, 0.0, servers).retire == [0]   # 10.0 + 2.0
    assert sc.next_retire_time(servers) == pytest.approx(12.0)


def test_retire_seconds_derive_from_legacy_ticks():
    """Configs that only set idle_ticks_before_retire keep their meaning:
    N ticks * tick_s seconds under any clock."""
    sc = Autoscaler(AutoscalerConfig(min_servers=0,
                                     idle_ticks_before_retire=10))
    servers = [_IdleSrv(0, idle_since=0.0)]
    assert sc.decide(0.45, 0, 0.0, servers, tick_s=0.05).retire == []
    assert sc.decide(0.50, 0, 0.0, servers, tick_s=0.05).retire == [0]
    # fakes without idle_since fall back to the tick counter
    bare = [_IdleSrv(1, idle_ticks=10)]
    del bare[0].idle_since
    assert sc.decide(0.0, 0, 0.0, bare).retire == [1]


def test_next_retire_time_respects_min_servers():
    sc = Autoscaler(AutoscalerConfig(min_servers=2,
                                     idle_seconds_before_retire=1.0))
    servers = [_IdleSrv(0, idle_since=0.0), _IdleSrv(1, idle_since=0.0)]
    assert sc.next_retire_time(servers) is None       # at the floor
    servers.append(_IdleSrv(2, idle_since=0.5))
    assert sc.next_retire_time(servers) == pytest.approx(1.0)


def test_wall_clock_retires_after_real_seconds():
    """The same time-based config under a WallClock: a server idle for
    idle_seconds_before_retire of REAL time retires (under the old
    tick-count scheme 2 ticks of wall time meant microseconds)."""
    sc = Autoscaler(AutoscalerConfig(min_servers=0,
                                     idle_seconds_before_retire=0.05))
    clock = WallClock()
    srv = _IdleSrv(0, idle_since=clock.now())
    assert sc.decide(clock.now(), 0, 0.0, [srv]).retire == []
    time.sleep(0.06)
    assert sc.decide(clock.now(), 0, 0.0, [srv]).retire == [0]


def test_logical_clock_sleep_until_never_rewinds():
    c = LogicalClock()
    c.advance(1.0)
    c.sleep_until(3.0)
    assert c.now() == pytest.approx(3.0)
    c.sleep_until(2.0)                      # jumps are forward-only
    assert c.now() == pytest.approx(3.0)


def test_wall_clock_sleep_until_blocks():
    c = WallClock()
    target = c.now() + 0.05
    c.sleep_until(target)
    assert c.now() >= target - 1e-9


# ---------------------------------------------------------------------------
# stale readiness estimates (the crash/restart cache bug)
# ---------------------------------------------------------------------------

def test_ready_est_invalidated_on_crash_and_rejoin(setup):
    """The cached rounds-to-ready estimate describes one load plan: a
    crash or restart replaces that plan, so the cache must die with it
    (SloAware would otherwise score a pre-crash readiness forever,
    because the cache is keyed by ``now`` and dispatch reuses one tick's
    ``now`` across the fleet)."""
    cfg, params = setup
    s = ClusterServer(0, cfg, params, ClusterConfig(n_devices=2, n_slots=2))
    assert s.state == "loading"
    s.predicted_ready_s(0.0)
    assert s._ready_est is not None
    s.crash()                               # whole-server: down
    assert s._ready_est is None
    s.rejoin()
    s.predicted_ready_s(1.0)
    assert s._ready_est is not None
    s.crash([0])                            # partial: survivors recover
    assert s.state == "recovering"
    assert s._ready_est is None


# ---------------------------------------------------------------------------
# overlapping faults: double crash, rejoin racing retirement
# ---------------------------------------------------------------------------

def test_double_crash_same_server_is_consistent():
    """Crashing an already-down server is a no-op: no double-drain, no
    duplicate crash bookkeeping, and the later rejoin still reboots it."""
    trace = _sim_trace()
    r = _sim_router()
    arrivals = sorted(trace, key=lambda a: a.time)
    i, crashes = 0, 0
    done = []
    for _ in range(200_000):
        while i < len(arrivals) and arrivals[i].time <= r.clock:
            r.submit(arrivals[i])
            i += 1
        done.extend(r.tick())
        if crashes == 0 and r.servers[1].load > 0:
            r.crash_server(1)
            drained_twice = r.servers[1].crash()   # second fault: no-op
            assert drained_twice == []             # nothing left to drain
            assert r.servers[1].state == "down"
            r.rejoin_server(1)
            crashes = 1
        if i >= len(arrivals) and r.pending == 0:
            break
    assert crashes == 1, "crash scenario never armed"
    assert len(done) == len(trace)
    assert r.metrics.summary()["n_completed"] == len(trace)
    kinds = [k for _, k, _ in r.metrics.events]
    assert kinds.count("crash") == 1               # booked exactly once
    assert "rejoin" in kinds


def test_rejoin_racing_retirement_resolves_to_noop():
    """A scheduled rejoin landing after the autoscaler retired the server
    resolves to a surfaced no-op (``rejoin_skipped``): retirement is
    final, and the replay still completes on the rest of the fleet."""
    r = _sim_router()
    r.servers[1].retire()
    r.metrics.on_event(r.clock, "retire", "server1")
    r.rejoin_server(1)                             # the racing rejoin
    assert r.servers[1].state == "retired"
    kinds = [k for _, k, _ in r.metrics.events]
    assert "rejoin_skipped" in kinds
    assert "rejoin" not in kinds
    # a chaos-scheduled rejoin resolves to the schedule-level no-op
    # (``chaos_skip``), on both engines
    from repro.cluster import ChaosEvent, ChaosSchedule
    chaos = ChaosSchedule([ChaosEvent(0.213, "rejoin", 1)])
    trace = poisson_trace(20.0, 1.0, seed=4, max_new_tokens=3)
    for eng in ("event", "tick"):
        r2 = _sim_router()
        r2.servers[1].retire()
        done = r2.run(list(trace), engine=eng, chaos=chaos)
        assert len(done) == len(trace)
        kinds2 = [k for _, k, _ in r2.metrics.events]
        assert "chaos_skip" in kinds2
        assert "rejoin" not in kinds2
        assert r2.servers[1].state == "retired"
