"""Fleet state tier: prefix cache, spill/resurrect, engine bit-identity.

Covers the cross-request prefix cache store (serving/prefix_cache.py),
the host-side ``StateTier`` (cluster/state_tier.py), the real serving
engine's hit-import path (one donated scatter + suffix walk, streams
bit-identical to cold prefill, zero new compiles), and the cluster loop:
idle retirement spills warm state, a later spawn resurrects it, and the
tick and event engines replay the whole story identically.
"""
import dataclasses
import types

import numpy as np
import pytest

from repro.cluster import (Autoscaler, AutoscalerConfig, ClusterConfig,
                           ClusterMetrics, ClusterRouter, LogicalClock,
                           SimProfile, SloAware, StateTier,
                           repeated_prefix_trace, sim_server_factory)
from repro.cluster.traces import Arrival, prompt_tokens
from repro.serving.prefix_cache import PrefixCache, PrefixEntry, _lcp

RNG = np.random.default_rng(7)


def _toks(*vals):
    return np.asarray(vals, dtype=np.int64)


# ---------------------------------------------------------------------------
# PrefixCache store semantics (pure host, no JAX)
# ---------------------------------------------------------------------------

def test_lcp_basic():
    assert _lcp(_toks(1, 2, 3), _toks(1, 2, 3)) == 3
    assert _lcp(_toks(1, 2, 3), _toks(1, 2, 9, 9)) == 2
    assert _lcp(_toks(5), _toks(6)) == 0
    assert _lcp(_toks(), _toks(1, 2)) == 0


def test_probe_matches_shared_prefix_different_suffix():
    """The case per-length hashing provably fails: a donor prompt serves
    a new prompt sharing only a shorter prefix, with no entry ever
    inserted at that length."""
    pc = PrefixCache()
    donor = _toks(*range(10))
    pc.insert("m", None, donor, pos=10, rows=None, nbytes=100)
    query = np.concatenate([donor[:6], _toks(99, 98)])
    hit = pc.probe("m", None, query)
    assert hit is not None
    entry, k = hit
    assert k == 6
    pc.release(entry)
    # exact replay of the donor prompt: usable prefix is len-1 (one
    # suffix token must remain to produce the first sampled logits)
    _, k2 = pc.probe("m", None, donor)
    assert k2 == 9


def test_probe_keys_on_arch_and_adapter():
    pc = PrefixCache()
    t = _toks(1, 2, 3, 4)
    pc.insert("m", "lora-a", t, pos=4, rows=None, nbytes=10)
    assert pc.probe("m", None, t) is None
    assert pc.probe("other", "lora-a", t) is None
    assert pc.probe("m", "lora-a", t) is not None


def test_match_len_is_pure_read():
    pc = PrefixCache()
    t = _toks(1, 2, 3, 4)
    pc.insert("m", None, t, pos=4, rows=None, nbytes=10)
    assert pc.match_len("m", None, t) == 3
    assert pc.hits == 0 and pc.hit_tokens == 0
    e, _ = pc.probe("m", None, t)
    assert e.refs == 1 and pc.hits == 1
    pc.match_len("m", None, t)
    assert e.refs == 1                      # no extra pin from match_len
    pc.release(e)
    assert e.refs == 0


def test_insert_skips_covered_and_drops_dominated():
    pc = PrefixCache()
    long = _toks(*range(8))
    assert pc.insert("m", None, long, pos=8, rows=None, nbytes=80)
    # already covered: a shorter prefix of an existing entry is a no-op
    assert not pc.insert("m", None, long[:5], pos=5, rows=None, nbytes=50)
    assert pc.n_entries == 1
    # dominated in the other direction: a longer prompt whose prefix IS
    # the old entry's full tokens replaces it
    pc2 = PrefixCache()
    pc2.insert("m", None, long[:5], pos=5, rows=None, nbytes=50)
    pc2.insert("m", None, long, pos=8, rows=None, nbytes=80)
    assert pc2.n_entries == 1
    assert pc2.evictions == 1
    assert pc2.bytes_used == 80


def test_lru_eviction_respects_byte_budget_and_pins():
    pc = PrefixCache(capacity_bytes=250)
    a = _toks(1, 2, 3)
    b = _toks(4, 5, 6)
    c = _toks(7, 8, 9)
    pc.insert("m", None, a, pos=3, rows=None, nbytes=100)
    pc.insert("m", None, b, pos=3, rows=None, nbytes=100)
    ea, _ = pc.probe("m", None, np.concatenate([a, _toks(50)]))  # pin a
    pc.insert("m", None, c, pos=3, rows=None, nbytes=100)
    # budget forced one eviction; the pinned entry must have survived
    # even though it is NOT the most recently used
    assert pc.bytes_used <= 250 or any(
        e.refs for g in pc._groups.values() for e in g)
    assert pc.covers("m", None, a)
    assert not pc.covers("m", None, b)      # LRU victim was b
    assert pc.covers("m", None, c)
    pc.release(ea)
    assert pc.evictions >= 1


def test_insert_rowsless_requires_nbytes_and_respects_capacity():
    pc = PrefixCache(capacity_bytes=100)
    with pytest.raises(ValueError):
        pc.insert("m", None, _toks(1, 2), pos=2)
    # an entry larger than the whole budget is refused outright
    assert not pc.insert("m", None, _toks(1, 2), pos=2, nbytes=101)
    assert pc.insert("m", None, _toks(1, 2), pos=2, nbytes=99)


def test_export_import_round_trip():
    pc = PrefixCache()
    pc.insert("m", None, _toks(1, 2, 3), pos=3, rows=None, nbytes=30)
    pc.insert("m", "a", _toks(4, 5), pos=2, rows=None, nbytes=20)
    items = pc.export_entries()
    assert len(items) == 2
    fresh = PrefixCache()
    assert fresh.import_entries(items) == 2
    assert fresh.covers("m", None, _toks(1, 2, 3))
    assert fresh.covers("m", "a", _toks(4, 5))
    # re-import into the SAME cache is a covered no-op
    assert pc.import_entries(items) == 0


def test_stats_keys_stable():
    pc = PrefixCache()
    assert set(pc.stats()) == {
        "prefix_hits", "prefix_hit_tokens", "prefix_evictions",
        "prefix_insertions", "prefix_bytes", "prefix_entries"}


# ---------------------------------------------------------------------------
# StateTier bundle store
# ---------------------------------------------------------------------------

def _bundle(nb, entries=1):
    e = [(("m", None), PrefixEntry(tokens=_toks(i, i + 1), pos=2,
                                   rows=None, nbytes=nb // entries))
         for i in range(entries)]
    return {"prefix_entries": e, "adapters": {"a": object()}, "nbytes": nb}


def test_state_tier_spill_merge_and_take():
    tier = StateTier()
    tier.spill("p", _bundle(100, entries=2))
    tier.spill("p", _bundle(50))
    assert tier.peek_nbytes("p") == 150
    assert tier.pools == ["p"]
    got = tier.take("p")
    assert got is not None and got["nbytes"] == 150
    assert len(got["prefix_entries"]) == 3
    # exactly one spawn resurrects each spill generation
    assert tier.take("p") is None
    assert tier.peek_nbytes("p") == 0
    s = tier.stats()
    assert s["spill_count"] == 2.0
    assert s["spilled_bytes"] == 150.0
    assert s["spill_resurrections"] == 1.0
    assert s["resurrected_bytes"] == 150.0


def test_state_tier_pools_are_independent():
    tier = StateTier()
    tier.spill("a", _bundle(10))
    tier.spill(None, _bundle(20))            # standalone router: no pool
    assert tier.take("b") is None
    assert tier.take("a")["nbytes"] == 10
    assert tier.take(None)["nbytes"] == 20


# ---------------------------------------------------------------------------
# traces: shared-prefix prompt composition
# ---------------------------------------------------------------------------

def test_prompt_tokens_prefix_composition():
    a1 = Arrival(0.0, prompt_len=12, seed=1, prefix_len=8, prefix_seed=42)
    a2 = Arrival(1.0, prompt_len=12, seed=2, prefix_len=8, prefix_seed=42)
    t1, t2 = prompt_tokens(a1, 250), prompt_tokens(a2, 250)
    assert np.array_equal(t1[:8], t2[:8])
    assert not np.array_equal(t1[8:], t2[8:])
    # prefix_len=0 (and legacy records without the fields) keeps the
    # original single-draw content bit-for-bit
    legacy = Arrival(0.0, prompt_len=12, seed=1)
    expect = np.random.default_rng(1).integers(0, 250, size=12)
    assert np.array_equal(prompt_tokens(legacy, 250), expect)


def test_repeated_prefix_trace_shape():
    tr = repeated_prefix_trace(6, prefix_len=10, suffix_len=3,
                               n_prefixes=2, gap_s=0.07, seed=5)
    assert len(tr) == 6
    assert all(a.prompt_len == 13 for a in tr)
    assert tr[0].prefix_seed == tr[2].prefix_seed != tr[1].prefix_seed
    p0, p2 = prompt_tokens(tr[0], 250), prompt_tokens(tr[2], 250)
    assert np.array_equal(p0[:10], p2[:10])


# ---------------------------------------------------------------------------
# cluster loop: spill -> resurrect, tick == event (modeled backend)
# ---------------------------------------------------------------------------

def _tier_run(engine):
    ccfg = ClusterConfig(tick_s=0.05, n_slots=4, prefix_cache_bytes=64 << 20)
    auto = Autoscaler(AutoscalerConfig(min_servers=1, max_servers=2,
                                       idle_ticks_before_retire=20))
    # two bursts with an idle gap long enough to retire the scaled-up
    # server in between; gaps sit OFF the tick grid (see traces docs)
    wave1 = repeated_prefix_trace(16, prefix_len=24, suffix_len=4,
                                  gap_s=0.021, seed=0)
    wave2 = repeated_prefix_trace(12, prefix_len=24, suffix_len=4,
                                  gap_s=0.011, seed=100)
    trace = wave1 + [dataclasses.replace(a, time=a.time + 8.003)
                     for a in wave2]
    cfg = types.SimpleNamespace(vocab_size=250, name="m")
    r = ClusterRouter(cfg, None, n_servers=2, ccfg=ccfg, autoscaler=auto,
                      dispatch=SloAware(step_cost_s=0.05,
                                        prefix_bonus_s_per_token=0.001),
                      clock=LogicalClock(), metrics=ClusterMetrics(),
                      server_factory=sim_server_factory(SimProfile()),
                      state_tier=StateTier())
    done = r.run(trace, engine=engine)
    return r, {q.rid: tuple(q.generated) for q in done}


def test_spill_resurrect_cycle_tick_event_parity():
    r_evt, s_evt = _tier_run("event")
    r_tick, s_tick = _tier_run("tick")
    assert s_evt == s_tick and len(s_evt) == 28
    sum_evt, sum_tick = r_evt.metrics.summary(), r_tick.metrics.summary()
    for k in ("n_completed", "prefix_hits", "prefix_hit_tokens",
              "prefix_evictions", "spill_resurrections", "spilled_bytes",
              "hotpath_n_prefill_tokens"):
        assert abs(sum_evt[k] - sum_tick[k]) < 1e-9, (k, sum_evt[k],
                                                      sum_tick[k])
    assert sum_evt["prefix_hits"] > 0
    assert sum_evt["spill_resurrections"] == 1.0
    assert sum_evt["spilled_bytes"] > 0
    kinds = [k for _, k, _ in r_evt.metrics.events]
    assert "spill" in kinds and "resurrect" in kinds
    # the resurrected server starts warm: its cache holds the spilled
    # entries on top of whatever its own traffic deposited
    warm = [s for s in r_evt.servers if s.sid == 2]
    assert warm and warm[0].srv._pc.n_entries >= 8


def test_summary_keys_always_present_when_tier_off():
    """The five summary keys exist (as zeros) even for legacy runs with
    no prefix cache and no state tier."""
    m = ClusterMetrics()
    s = m.summary()
    for k in ("prefix_hits", "prefix_hit_tokens", "prefix_evictions",
              "spill_resurrections", "spilled_bytes"):
        assert s[k] == 0.0, k


def test_resurrect_cost_delays_modeled_readiness():
    """A big state-tier pull holds the spawn in ``loading`` past the
    normal ready point (max-overlap, not additive)."""
    ccfg = ClusterConfig(tick_s=0.05, n_slots=4, prefix_cache_bytes=1 << 20)
    from repro.cluster.simserver import SimServer
    s = SimServer(0, types.SimpleNamespace(name="m"), None, ccfg,
                  profile=SimProfile(ready_ticks=2))
    s.attach_prefix_cache(PrefixCache(1 << 20))
    bundle = {"prefix_entries": [(("m", None), PrefixEntry(
        tokens=_toks(1, 2, 3), pos=3, rows=None, nbytes=64))],
        "adapters": {}, "nbytes": 64}
    n = s.resurrect_from(bundle, cost_s=0.25)   # 5 ticks > ready_ticks=2
    assert n == 1
    assert s.predicted_ready_s(0.0) == pytest.approx(0.25)
    ticks = 0
    while s.state == "loading":
        s.tick(ticks * 0.05)
        ticks += 1
    assert ticks == 5                           # held by the pull, not 2


def test_slo_aware_prefix_bonus_steers_dispatch():
    """With the bonus on, a warm-cache server wins a dispatch it would
    otherwise tie/lose; with the default 0 the scoring is unchanged."""
    ccfg = ClusterConfig(tick_s=0.05, n_slots=4)
    cold = SimProfile()
    mk = sim_server_factory(cold)
    s0 = mk(0, types.SimpleNamespace(name="m"), None, ccfg)
    s1 = mk(1, types.SimpleNamespace(name="m"), None, ccfg)
    for s in (s0, s1):
        s.state = "serving"
    pc = PrefixCache()
    warm_prompt = _toks(*range(20))
    pc.insert("m", None, warm_prompt, pos=20, rows=None, nbytes=20 << 10)
    s1.attach_prefix_cache(pc)
    from repro.serving.engine import ServeRequest
    req = ServeRequest(0, np.concatenate([warm_prompt[:16], _toks(9, 9)]),
                       max_new_tokens=4)
    plain = SloAware(step_cost_s=0.05)
    t0 = plain.predicted_first_token_s(s0, req, 0.0, ccfg)
    t1 = plain.predicted_first_token_s(s1, req, 0.0, ccfg)
    assert t0 == t1                              # default: no steering
    bonus = SloAware(step_cost_s=0.05, prefix_bonus_s_per_token=0.01)
    t1b = bonus.predicted_first_token_s(s1, req, 0.0, ccfg)
    t0b = bonus.predicted_first_token_s(s0, req, 0.0, ccfg)
    assert t1b == pytest.approx(t0b - 0.01 * 16)
    pick = bonus.select([req], [s0, s1], 0.0, ccfg)
    assert pick is not None and pick[1] is s1


# ---------------------------------------------------------------------------
# real serving engine: hit import is bit-identical and compile-free
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs.base import get_arch
    from repro.models import transformer as T
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    return cfg, params


def _batcher(cfg, params, cache=None):
    from repro.serving.engine import ContinuousBatcher, quantized_greedy
    cb = ContinuousBatcher(cfg, params, n_slots=4, max_len=96,
                           sampler=quantized_greedy)
    if cache is not None:
        cb.attach_prefix_cache(cache)
    return cb


def _serve(cb, prompts, n_new=6):
    from repro.serving.engine import ServeRequest
    reqs = [ServeRequest(i, p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    cb.admit_batch(reqs)
    while cb.n_active:
        cb.step()
    return [tuple(r.generated) for r in reqs]


def test_prefix_hit_streams_bit_identical(setup):
    """Shared-prefix prompts served through the cache produce EXACTLY the
    cold-prefill token streams, with fewer prefill tokens and zero new
    decode/prefill compiles."""
    cfg, params = setup
    pre = RNG.integers(0, 250, size=24)
    prompts = [np.concatenate([pre, RNG.integers(0, 250, size=4)])
               for _ in range(2)]
    cold = _serve(_batcher(cfg, params), prompts)
    pc = PrefixCache()
    cb = _batcher(cfg, params, cache=pc)
    warm0 = _serve(cb, [prompts[0]])          # miss; deposits on finish
    assert warm0[0] == cold[0]
    assert cb.prefix_hits == 0 and pc.n_entries == 1
    base_tokens = cb.n_prefill_tokens
    comp0 = {k: cb.hotpath_stats()[k]
             for k in ("decode_compiles", "prefill_compiles")}
    warm1 = _serve(cb, [prompts[1]])          # hits the deposited prefix
    assert warm1[0] == cold[1]
    assert cb.prefix_hits == 1 and cb.prefix_hit_tokens == 24
    # only the 4-token suffix was walked, not the 28-token prompt
    assert cb.n_prefill_tokens - base_tokens == 4
    comp1 = {k: cb.hotpath_stats()[k]
             for k in ("decode_compiles", "prefill_compiles")}
    assert comp1 == comp0, "prefix import triggered a fresh compile"


def test_full_prompt_replay_hits_len_minus_one(setup):
    """Replaying an identical prompt reuses len-1 cached tokens (one
    suffix token must remain to sample from)."""
    cfg, params = setup
    prompt = RNG.integers(0, 250, size=16)
    cold = _serve(_batcher(cfg, params), [prompt])
    pc = PrefixCache()
    cb = _batcher(cfg, params, cache=pc)
    assert _serve(cb, [prompt]) == cold
    assert _serve(cb, [prompt]) == cold
    assert cb.prefix_hits == 1 and cb.prefix_hit_tokens == 15


def test_hit_admission_mid_decode_is_transparent(setup):
    """A prefix-hit admission landing while another request is mid-decode
    leaves every stream bit-identical (the suffix walk freezes live slots
    the same way snapshot imports do)."""
    cfg, params = setup
    from repro.serving.engine import ServeRequest
    pre = RNG.integers(0, 250, size=20)
    shared = [np.concatenate([pre, RNG.integers(0, 250, size=4)])
              for _ in range(2)]
    lone = RNG.integers(0, 250, size=11)

    def run(cache):
        cb = _batcher(cfg, params, cache=cache)
        r_lone = ServeRequest(99, lone, max_new_tokens=8)
        cb.admit_batch([r_lone])
        cb.step()                             # lone is mid-decode
        rs = [ServeRequest(i, p, max_new_tokens=5)
              for i, p in enumerate(shared)]
        cb.admit_batch([rs[0]])
        while rs[0].done is False and cb.n_active:
            cb.step()
        cb.admit_batch([rs[1]])               # hit, lone still decoding
        while cb.n_active:
            cb.step()
        return [tuple(r.generated) for r in rs + [r_lone]]

    cold = run(None)
    pc = PrefixCache()
    assert run(pc) == cold
    assert pc.hits >= 1


def test_spill_resurrect_real_rows_round_trip(setup):
    """Entries exported from one server's cache (real KV rows) resurrect
    into a fresh server and serve bit-identically via the import path."""
    cfg, params = setup
    pre = RNG.integers(0, 250, size=24)
    prompts = [np.concatenate([pre, RNG.integers(0, 250, size=4)])
               for _ in range(2)]
    cold = _serve(_batcher(cfg, params), prompts)
    pc_a = PrefixCache()
    cb_a = _batcher(cfg, params, cache=pc_a)
    assert _serve(cb_a, [prompts[0]]) == [cold[0]]
    spilled = pc_a.export_entries()           # what a retirement spills
    assert spilled and all(e.rows is not None for _, e in spilled)
    pc_b = PrefixCache()
    assert pc_b.import_entries(spilled) == len(spilled)
    cb_b = _batcher(cfg, params, cache=pc_b)
    assert _serve(cb_b, [prompts[1]]) == [cold[1]]
    assert cb_b.prefix_hits == 1 and cb_b.prefix_hit_tokens == 24


def test_drain_deposits_inflight_prompts(setup):
    """drain() deposits the prompts of in-flight requests, so retiring a
    busy server still warms the tier for its successors."""
    cfg, params = setup
    from repro.serving.engine import ServeRequest
    prompt = RNG.integers(0, 250, size=14)
    pc = PrefixCache()
    cb = _batcher(cfg, params, cache=pc)
    r = ServeRequest(0, prompt, max_new_tokens=10)
    cb.admit_batch([r])
    cb.step()                                 # in flight, not finished
    assert pc.n_entries == 0
    cb.drain(export_state=True)
    assert pc.n_entries == 1
    assert pc.covers(cfg.name, None, np.asarray(prompt, np.int64),
                     pos=len(prompt))
