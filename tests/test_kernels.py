"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rnd(shape, dtype, salt):
    x = jax.random.normal(jax.random.fold_in(KEY, salt), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,d", [
    (1, 128, 128, 4, 4, 64),     # MHA square
    (2, 200, 200, 8, 2, 64),     # GQA, ragged block edge
    (1, 64, 256, 4, 1, 128),     # MQA, cross attention lengths
    (2, 33, 130, 2, 2, 32),      # non-aligned everything
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(B, Sq, Sk, Hq, Hkv, d, dtype, causal, window):
    if causal and Sq != Sk:
        pytest.skip("causal assumes aligned q/k starts here")
    q = rnd((B, Sq, Hq, d), dtype, 1)
    k = rnd((B, Sk, Hkv, d), dtype, 2)
    v = rnd((B, Sk, Hkv, d), dtype, 3)
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            block_q=64, block_k=64)
    r = ref.flash_attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                                jnp.moveaxis(v, 1, 2), causal=causal,
                                window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(jnp.moveaxis(r, 1, 2), np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,C,Hq,Hkv,d,block_k", [
    (2, 256, 8, 8, 64, 128),
    (3, 300, 8, 2, 64, 128),     # GQA + pad
    (1, 1024, 4, 1, 128, 512),   # MQA long cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, C, Hq, Hkv, d, block_k, dtype):
    q = rnd((B, 1, Hq, d), dtype, 4)
    k = rnd((B, C, Hkv, d), dtype, 5)
    v = rnd((B, C, Hkv, d), dtype, 6)
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, C + 1, size=B), jnp.int32)
    o = ops.decode_attention(q, k, v, lens, block_k=block_k)
    r = ref.decode_attention_ref(q[:, 0], jnp.moveaxis(k, 1, 2),
                                 jnp.moveaxis(v, 1, 2), lens)
    np.testing.assert_allclose(np.asarray(o[:, 0], np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,C,Hq,Hkv,d", [
    (3, 256, 8, 2, 64),
    (2, 300, 4, 4, 32),              # pad path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_merged_new_token(B, C, Hq, Hkv, d, dtype):
    """Zero-copy serving mode: the current token's K/V merged in-kernel
    must equal writing it at position ``lens`` and attending over lens+1
    entries — for ragged per-slot lens including the 0 and C-1 extremes."""
    q = rnd((B, 1, Hq, d), dtype, 30)
    k = rnd((B, C, Hkv, d), dtype, 31)
    v = rnd((B, C, Hkv, d), dtype, 32)
    kn = rnd((B, 1, Hkv, d), dtype, 33)
    vn = rnd((B, 1, Hkv, d), dtype, 34)
    lens = np.random.default_rng(1).integers(1, C - 1, size=B)
    lens[0] = 0                       # slot fresh out of (empty) prefill
    lens[-1] = C - 1                  # slot about to fill its cache
    lens = jnp.asarray(lens, jnp.int32)
    o = ops.decode_attention(q, k, v, lens, k_new=kn, v_new=vn, block_k=128)
    # oracle: write the new token into the cache, then plain ragged decode
    bidx = jnp.arange(B)
    kw = k.at[bidx, lens].set(kn[:, 0])
    vw = v.at[bidx, lens].set(vn[:, 0])
    r = ref.decode_attention_ref(q[:, 0], jnp.moveaxis(kw, 1, 2),
                                 jnp.moveaxis(vw, 1, 2), lens + 1)
    np.testing.assert_allclose(np.asarray(o[:, 0], np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,C,Hq,Hkv,d,block_k", [
    (3, 40, 8, 2, 64, 16),           # GQA, mask straddles block edges
    (2, 300, 4, 4, 32, 128),         # pad path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("merge_new", [False, True])
def test_decode_attention_slot_mask(B, C, Hq, Hkv, d, block_k, dtype,
                                    merge_new):
    """Ring-buffer mode: per-slot validity mask (eviction) must match the
    oracle — with and without the zero-copy in-kernel new-token merge."""
    q = rnd((B, 1, Hq, d), dtype, 50)
    k = rnd((B, C, Hkv, d), dtype, 51)
    v = rnd((B, C, Hkv, d), dtype, 52)
    rng = np.random.default_rng(2)
    lens = jnp.asarray(rng.integers(0, C + 1, size=B), jnp.int32)
    sm = rng.integers(0, 2, size=(B, C)).astype(bool)
    sm[0, :] = True                   # one fully-valid row
    kwargs = {}
    if merge_new:
        kwargs["k_new"] = rnd((B, 1, Hkv, d), dtype, 53)
        kwargs["v_new"] = rnd((B, 1, Hkv, d), dtype, 54)
    o = ops.decode_attention(q, k, v, lens, slot_mask=jnp.asarray(sm),
                             block_k=block_k, **kwargs)
    if merge_new:
        # oracle: write the new token at the ring slot (pos % C), mark the
        # slot valid, and attend over min(lens+1, C) entries
        bidx = jnp.arange(B)
        slot = jnp.mod(lens, C)
        kw = k.at[bidx, slot].set(kwargs["k_new"][:, 0])
        vw = v.at[bidx, slot].set(kwargs["v_new"][:, 0])
        smw = jnp.asarray(sm).at[bidx, slot].set(True)
        r = ref.decode_attention_ref(q[:, 0], jnp.moveaxis(kw, 1, 2),
                                     jnp.moveaxis(vw, 1, 2),
                                     jnp.minimum(lens + 1, C), slot_mask=smw)
    else:
        r = ref.decode_attention_ref(q[:, 0], jnp.moveaxis(k, 1, 2),
                                     jnp.moveaxis(v, 1, 2), lens,
                                     slot_mask=jnp.asarray(sm))
    np.testing.assert_allclose(np.asarray(o[:, 0], np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_windowed_decode_step_pallas_matches_xla():
    """Ring-buffer (windowed) decode under eviction: the slot-masked Pallas
    flash-decode must produce the same logits/cache as the XLA lowering —
    the windowed zero-copy path no longer pins to XLA (ROADMAP item)."""
    from repro.configs.base import get_arch
    from repro.models import attention as A
    from repro.models import transformer as T
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2, attn_window=8)
    params = T.init_params(cfg, KEY)
    # prompt longer than the window: the ring is full and every further
    # decode step evicts (the slot mask is live, not vacuous)
    prompt = jax.random.randint(jax.random.fold_in(KEY, 60), (2, 12), 0, 250)
    lg, cache0 = T.forward(cfg, params, {"tokens": prompt}, mode="prefill",
                           max_len=32)
    tok0 = jnp.argmax(lg, -1).astype(jnp.int32)
    outs = {}
    for impl in ("xla", "pallas"):
        cache = jax.tree.map(lambda a: a, cache0)
        tok = tok0
        toks = []
        with A.decode_attn_impl(impl):
            for _ in range(6):
                lg, cache = T.decode_step(cfg, params, {"tokens": tok}, cache)
                tok = jnp.argmax(lg, -1).astype(jnp.int32)
                toks.append(np.asarray(tok))
        outs[impl] = (np.stack(toks), cache)
    np.testing.assert_array_equal(outs["xla"][0], outs["pallas"][0])
    for leaf in outs["xla"][1]["attn"]:
        np.testing.assert_allclose(
            np.asarray(outs["xla"][1]["attn"][leaf]),
            np.asarray(outs["pallas"][1]["attn"][leaf]), atol=1e-5, rtol=1e-5)


def test_decode_step_pallas_matches_xla():
    """transformer.decode_step behind the backend dispatch: the Pallas
    flash-decode path (interpret mode here, Mosaic on TPU) must match the
    XLA online-softmax path on ragged per-slot cache lengths."""
    from repro.configs.base import get_arch
    from repro.models import attention as A
    from repro.models import transformer as T
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    params = T.init_params(cfg, KEY)
    prompt = jax.random.randint(jax.random.fold_in(KEY, 40), (2, 12), 0, 250)
    lg, cache = T.forward(cfg, params, {"tokens": prompt}, mode="prefill",
                          max_len=32)
    cache["pos"] = jnp.asarray([12, 7], jnp.int32)    # ragged slot lens
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    with A.decode_attn_impl("xla"):
        lx, cx = T.decode_step(cfg, params, {"tokens": tok}, cache)
    with A.decode_attn_impl("pallas"):
        lp, cp = T.decode_step(cfg, params, {"tokens": tok}, cache)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               atol=1e-4, rtol=1e-4)
    for grp in ("attn",):
        for leaf in cx[grp]:
            np.testing.assert_allclose(np.asarray(cx[grp][leaf]),
                                       np.asarray(cp[grp][leaf]),
                                       atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 130, 4, 32, 16, 32),     # pad path
    (1, 256, 8, 64, 128, 64),    # mamba2-780m-like dims
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    x = rnd((B, S, H, P), jnp.float32, 7)
    dt = jax.nn.softplus(rnd((B, S, H), jnp.float32, 8))
    A = -jnp.exp(rnd((H,), jnp.float32, 9) * 0.3)
    Bm = rnd((B, S, N), jnp.float32, 10)
    Cm = rnd((B, S, N), jnp.float32, 11)
    y, fs = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, fsr = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr),
                               atol=5e-4, rtol=5e-4)


def test_ssd_scan_matches_model_chunked_form():
    """Kernel vs the model's associative-scan SSD (two independent paths)."""
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, N = 2, 96, 4, 16, 8
    x = rnd((B, S, H, P), jnp.float32, 12)
    dt = jax.nn.softplus(rnd((B, S, H), jnp.float32, 13))
    A = -jnp.exp(rnd((H,), jnp.float32, 14) * 0.3)
    Bm = rnd((B, S, N), jnp.float32, 15)
    Cm = rnd((B, S, N), jnp.float32, 16)
    y1, fs1 = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    y2, fs2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(fs1), np.asarray(fs2),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("B,S,W,bt,bw", [
    (1, 64, 32, 32, 32),
    (2, 100, 48, 32, 16),        # pad both dims
    (1, 256, 128, 128, 128),
])
def test_rglru_scan_sweep(B, S, W, bt, bw):
    la = -jax.nn.softplus(rnd((B, S, W), jnp.float32, 17))
    bx = rnd((B, S, W), jnp.float32, 18)
    h0 = rnd((B, W), jnp.float32, 19)
    y, hT = ops.rglru_scan(la, bx, h0, block_t=bt, block_w=bw)
    yr, hTr = ref.rglru_scan_ref(la, bx, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr),
                               atol=1e-4, rtol=1e-4)


def test_rglru_matches_model_scan():
    from repro.models.rglru import rglru_scan as model_scan
    B, S, W = 2, 80, 32
    la = -jax.nn.softplus(rnd((B, S, W), jnp.float32, 20))
    bx = rnd((B, S, W), jnp.float32, 21)
    h0 = rnd((B, W), jnp.float32, 22)
    y1, h1 = ops.rglru_scan(la, bx, h0, block_t=16, block_w=16)
    y2, h2 = model_scan(la, bx, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("L,Din,Dout,r,bi,bj", [
    (1, 64, 64, 4, 32, 32),
    (3, 96, 160, 8, 32, 64),
    (2, 100, 100, 16, 64, 64),   # pad path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_merge_sweep(L, Din, Dout, r, bi, bj, dtype):
    W = rnd((L, Din, Dout), dtype, 23)
    A = rnd((L, Din, r), dtype, 24)
    B = rnd((L, r, Dout), dtype, 25)
    o = ops.lora_merge(W, A, B, 0.25, block_i=bi, block_j=bj)
    r_ = ref.lora_merge_ref(W, A, B, 0.25)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r_, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_lora_merge_unmerge_roundtrip():
    W = rnd((2, 64, 64), jnp.float32, 26)
    A = rnd((2, 64, 8), jnp.float32, 27)
    B = rnd((2, 8, 64), jnp.float32, 28)
    merged = ops.lora_merge(W, A, B, 0.5)
    back = ops.lora_merge(merged, A, B, -0.5)
    np.testing.assert_allclose(np.asarray(back), np.asarray(W), atol=1e-5)
