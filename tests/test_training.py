"""Training substrate: optimizer math, loss descent, grad accumulation,
checkpoint/restart determinism (fault-tolerance requirement)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.training.checkpoint import Checkpointer
from repro.training.data import CorpusLM, SyntheticLM
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, lr_at)
from repro.training.train import (cross_entropy, init_train_state,
                                  make_train_step)

KEY = jax.random.PRNGKey(5)


def test_adamw_against_naive_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10,
                      min_lr_frac=1.0)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    grads = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    state = init_opt_state(params)
    new_p, new_s, _ = adamw_update(cfg, params, grads, state)
    # naive: m = .1*g; v = .01*g^2; mhat = m/(1-.9); vhat = v/(1-.99)
    g = np.asarray(grads["w"])
    mhat = 0.1 * g / (1 - 0.9)
    vhat = 0.01 * g * g / (1 - 0.99)
    ref = np.asarray(params["w"]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, atol=1e-6)


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) < 0.2
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 0.1
    assert abs(float(lr_at(cfg, jnp.asarray(110))) - 0.1) < 0.02


def test_cross_entropy_ignores_masked():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    labels = jnp.asarray([[1, 2, -1, -1]])
    loss, n = cross_entropy(logits, labels)
    assert float(n) == 2
    np.testing.assert_allclose(float(loss), np.log(8), atol=1e-5)


def test_loss_decreases():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)
    state = init_train_state(cfg, KEY, jnp.float32)
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                     seed=3)
    losses = []
    for _ in range(30):
        b = ds.next_batch()
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_grad_accumulation_equivalence():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    state = init_train_state(cfg, KEY, jnp.float32)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=8,
                     seed=1)
    b = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    s1, m1 = make_train_step(cfg, opt, remat=False, accum=1)(state, b)
    s2, m2 = make_train_step(cfg, opt, remat=False, accum=4)(state, b)
    # same loss and near-identical parameters
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               atol=1e-4)
    for a, c in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-4)


def test_checkpoint_restart_exact_resume():
    """Train 6 steps straight == train 3, checkpoint, restart, train 3."""
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(cfg, opt, remat=False))

    def run(n, state, ds):
        for _ in range(n):
            b = ds.next_batch()
            state, _ = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        return state

    ds_a = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=4,
                       seed=9)
    ref = run(6, init_train_state(cfg, KEY, jnp.float32), ds_a)

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ds_b = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           batch_size=4, seed=9)
        st = run(3, init_train_state(cfg, KEY, jnp.float32), ds_b)
        ck.save(3, st, extra={"data": ds_b.state()}, async_=True)
        ck.wait()
        # "crash": fresh process state, restore everything
        tmpl = init_train_state(cfg, KEY, jnp.float32)
        st2, extra = ck.restore(tmpl)
        ds_c = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           batch_size=4, seed=9)
        ds_c.restore(extra["data"])
        got = run(3, st2, ds_c)

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_data_pipeline_determinism_and_sharding():
    a = SyntheticLM(vocab_size=100, seq_len=8, batch_size=4, seed=4)
    b = SyntheticLM(vocab_size=100, seq_len=8, batch_size=4, seed=4)
    np.testing.assert_array_equal(a.next_batch()["tokens"],
                                  b.next_batch()["tokens"])
    r0 = SyntheticLM(vocab_size=100, seq_len=8, batch_size=4, seed=4,
                     ).shard(0, 2)
    r1 = SyntheticLM(vocab_size=100, seq_len=8, batch_size=4, seed=4,
                     ).shard(1, 2)
    assert not np.array_equal(r0.next_batch()["tokens"],
                              r1.next_batch()["tokens"])


def test_corpus_data():
    ds = CorpusLM(text="hello world " * 100, seq_len=16, batch_size=2)
    b = ds.next_batch()
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_gradient_compression_bf16():
    from repro.training.train import compress_grads
    g = {"w": jnp.ones((4, 4), jnp.float32) * 1.2345678}
    c = compress_grads(g, "bf16")
    assert c["w"].dtype == jnp.bfloat16
