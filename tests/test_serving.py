"""Serving: continuous batching exactness + adapter epoch scheduling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.adapter_scheduler import (EagerPolicy, EpochSchedulerPolicy,
                                          simulate_adapter_serving)
from repro.models import transformer as T
from repro.serving.engine import ContinuousBatcher, ServeRequest, ServingEngine

KEY = jax.random.PRNGKey(2)


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _qargmax(lg):
    """Tie-robust greedy sampler: quantize before argmax so sub-1e-3 fp
    differences between batched and solo kernels can't flip the pick."""
    return jnp.argmax(jnp.round(lg.astype(jnp.float32) * 1e3), axis=-1)


def _solo(cfg, params, prompt, n):
    lg, cache = T.forward(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                          mode="prefill", max_len=96)
    toks = [int(_qargmax(lg)[0])]
    for _ in range(n - 1):
        lg, cache = T.decode_step(
            cfg, params, {"tokens": jnp.asarray([toks[-1]], jnp.int32)},
            cache)
        toks.append(int(_qargmax(lg)[0]))
    return toks


def test_continuous_batching_matches_solo(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, n_slots=3, max_len=96,
                           sampler=_qargmax)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 250, size=8 + 5 * i) for i in range(3)]
    reqs = [ServeRequest(i, p, max_new_tokens=6) for i, p in
            enumerate(prompts)]
    # staggered admissions while others decode
    cb.admit(reqs[0])
    cb.step()
    cb.admit(reqs[1])
    cb.step()
    cb.admit(reqs[2])
    while cb.n_active:
        cb.step()
    for i, p in enumerate(prompts):
        assert reqs[i].generated == _solo(cfg, params, p, 6), i


def test_slot_reuse(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, n_slots=1, max_len=64,
                           sampler=_qargmax)
    rng = np.random.default_rng(1)
    for i in range(3):  # three sequential requests through one slot
        r = ServeRequest(i, rng.integers(0, 250, size=6), max_new_tokens=4)
        assert cb.admit(r)
        while cb.n_active:
            cb.step()
        assert r.generated == _solo(cfg, params, r.tokens, 4)


def test_serving_engine_adapter_epochs(setup):
    cfg, params = setup
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    lora = randomize_lora(jax.random.fold_in(KEY, 7),
                          init_lora(KEY, cfg, rank=4))
    merged = merge_lora(params, lora)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64,
                        policy=EpochSchedulerPolicy(epoch_budget=2,
                                                    max_batch=2),
                        adapter_params={"a": merged})
    eng.batcher.sampler = _qargmax
    rng = np.random.default_rng(2)
    reqs = []
    for i in range(6):
        r = ServeRequest(i, rng.integers(0, 250, size=6), max_new_tokens=3,
                         adapter="a" if i % 2 else None)
        reqs.append(r)
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6
    # epoch scheduling groups adapters: far fewer switches than requests
    assert eng.n_adapter_switches <= 4
    # outputs match the right parameter set
    for r in reqs:
        p = merged if r.adapter == "a" else params
        assert r.generated == _solo(cfg, p, r.tokens, 3), r.rid


def test_bucketed_prefill_matches_solo(setup):
    """Padded (bucketed) prefill — including a batched same-bucket admit —
    is token-for-token identical to the unpadded solo path."""
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, n_slots=4, max_len=96,
                           sampler=_qargmax)
    assert cb._can_bucket
    rng = np.random.default_rng(3)
    # lengths straddling buckets: 5, 13 -> 16-pad; 23 -> 32-pad; 50 -> 64-pad
    prompts = [rng.integers(0, 250, size=L) for L in (5, 13, 23, 50)]
    reqs = [ServeRequest(i, p, max_new_tokens=5) for i, p in
            enumerate(prompts)]
    cb.admit_batch(reqs[:2])          # one padded batched prefill call
    cb.step()
    cb.admit(reqs[2])                 # staggered admissions mid-decode
    cb.step()
    cb.admit(reqs[3])
    while cb.n_active:
        cb.step()
    for i, p in enumerate(prompts):
        assert reqs[i].generated == _solo(cfg, params, p, 5), i
    # 3 distinct buckets (16, 32, 64), batched call counts once
    assert cb.n_prefill_calls == 3
    cs = cb.compile_stats()
    if cs["prefill_compiles"] >= 0:   # -1 = cache-size API gone, not a bug
        assert 0 < cs["prefill_compiles"] <= 3
        assert cs["decode_compiles"] == 1


def test_free_slots_are_inert(setup):
    """Inactive slots must not advance position or corrupt later
    admissions: their pos is frozen in-jit and their token is passed
    through (no EOS-dependent sampler edge cases on garbage logits)."""
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, n_slots=3, max_len=96,
                           sampler=_qargmax)
    rng = np.random.default_rng(4)
    r0 = ServeRequest(0, rng.integers(0, 250, size=7), max_new_tokens=6)
    cb.admit(r0)
    for _ in range(4):
        cb.step()
    pos = np.asarray(cb.cache["pos"])
    for slot in cb.free:
        assert pos[slot] == 0, (slot, pos)
    # a request admitted into a previously-idle slot decodes exactly
    r1 = ServeRequest(1, rng.integers(0, 250, size=9), max_new_tokens=4)
    cb.admit(r1)
    while cb.n_active:
        cb.step()
    assert r0.generated == _solo(cfg, params, r0.tokens, 6)
    assert r1.generated == _solo(cfg, params, r1.tokens, 4)


def test_adapter_switch_does_not_recompile(setup):
    """Params are a traced argument: epoch switches swap the pointer, the
    fused decode step must never retrace (satellite of the hot-path PR)."""
    cfg, params = setup
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    lora = randomize_lora(jax.random.fold_in(KEY, 9),
                          init_lora(KEY, cfg, rank=4))
    merged = merge_lora(params, lora)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64,
                        policy=EpochSchedulerPolicy(epoch_budget=2,
                                                    max_batch=2),
                        adapter_params={"a": merged})
    eng.batcher.sampler = _qargmax
    rng = np.random.default_rng(5)
    for i in range(6):
        eng.submit(ServeRequest(i, rng.integers(0, 250, size=6),
                                max_new_tokens=3,
                                adapter="a" if i % 2 else None))
    done = eng.run()
    assert len(done) == 6
    assert eng.n_adapter_switches >= 2
    cs = eng.batcher.compile_stats()
    if cs["decode_compiles"] >= 0:    # -1 = cache-size API gone, not a bug
        assert cs["decode_compiles"] == 1, cs


def test_epoch_scheduler_beats_eager_at_load():
    """Paper Fig. 14: epoch-based switching cuts mean latency and merges."""
    epoch = simulate_adapter_serving(
        EpochSchedulerPolicy(epoch_budget=8, max_batch=8),
        rps=20.0, horizon=30.0, switch_prob=0.2)
    eager = simulate_adapter_serving(
        EagerPolicy(max_batch=8),
        rps=20.0, horizon=30.0, switch_prob=0.2)
    assert epoch["merges"] < eager["merges"]
    assert epoch["mean"] < eager["mean"] * 0.7   # paper: 63% cut @25RPS


def test_epoch_scheduler_drains_everything():
    for pol in (EpochSchedulerPolicy(epoch_budget=3, max_batch=4),
                EagerPolicy(max_batch=4)):
        out = simulate_adapter_serving(pol, rps=5.0, horizon=10.0,
                                       n_adapters=3, switch_prob=0.5)
        assert out["n"] > 0
