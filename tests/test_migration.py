"""KV-snapshot migration: export -> import resumes decode with zero
re-prefill, token-for-token identical to an uninterrupted run (the
cluster-level §4.4 claim: surviving hardware keeps serving without
redoing prefill)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import (ContinuousBatcher, ServeRequest,
                                  ServingEngine, quantized_greedy)

KEY = jax.random.PRNGKey(11)


def _solo(cfg, params, prompt, n, max_len=96):
    lg, cache = T.forward(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                          mode="prefill", max_len=max_len)
    toks = [int(quantized_greedy(lg)[0])]
    for _ in range(n - 1):
        lg, cache = T.decode_step(
            cfg, params, {"tokens": jnp.asarray([toks[-1]], jnp.int32)},
            cache)
        toks.append(int(quantized_greedy(lg)[0]))
    return toks


def _engine(cfg, params, n_slots=2, max_len=96):
    e = ServingEngine(cfg, params, n_slots=n_slots, max_len=max_len)
    e.batcher.sampler = quantized_greedy
    return e


@pytest.mark.parametrize("arch,kw", [
    ("qwen3-1.7b", {}),                          # dense, full-length cache
    ("qwen3-1.7b", {"attn_window": 8}),          # pure-attn ring buffer
    ("recurrentgemma-2b", {"attn_window": 8}),   # hybrid rec + ring
    ("mamba2-780m", {}),                         # SSM state only
])
def test_migration_roundtrip_matches_solo(arch, kw):
    """Drain mid-decode -> import on a fresh engine -> identical greedy
    tokens, with ZERO prefill work on the survivor.  The ring cases use a
    prompt longer than the window, so the tail-keep prefill branch and the
    wrapped-ring slot layout both ride through the snapshot."""
    cfg = get_arch(arch).reduced(n_layers=4, **kw)
    params = T.init_params(cfg, KEY)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 250, size=20)       # > window in ring cases
    a = _engine(cfg, params)
    req = ServeRequest(0, prompt, max_new_tokens=10)
    a.submit(req)
    for _ in range(4):
        a.step()
    drained = a.drain_inflight()
    assert drained == [req]
    assert req.snapshot is not None
    assert 1 < len(req.generated) < 10
    # snapshot pos == tokens whose state travelled (prompt + prefix - 1)
    assert req.snapshot.pos == len(prompt) + len(req.generated) - 1

    b = _engine(cfg, params)
    assert b.admit_with_state(req)
    assert req.snapshot is None                  # consumed
    assert b.batcher.n_prefill_reqs == 0
    assert b.batcher.n_migrated_in == 1
    while b.batcher.n_active:
        b.step()
    assert req.done
    assert req.generated == _solo(cfg, params, prompt, 10)
    assert b.batcher.n_prefill_reqs == 0         # never prefetched a token


def test_migration_into_busy_batch_exact():
    """Import lands in a free slot of a batch that is mid-decode on other
    requests; neither the import nor the residents diverge."""
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    rng = np.random.default_rng(1)
    p_res = rng.integers(0, 250, size=9)
    p_mig = rng.integers(0, 250, size=14)

    a = _engine(cfg, params)
    mig = ServeRequest(7, p_mig, max_new_tokens=8)
    a.submit(mig)
    for _ in range(3):
        a.step()
    a.drain_inflight()

    b = _engine(cfg, params, n_slots=3)
    res = ServeRequest(1, p_res, max_new_tokens=9)
    b.submit(res)
    b.step()
    b.step()
    assert b.admit_with_state(mig)
    while b.batcher.n_active:
        b.step()
    assert mig.generated == _solo(cfg, params, p_mig, 8)
    assert res.generated == _solo(cfg, params, p_res, 9)


def test_import_refuses_incompatible_snapshot():
    """Shape/identity mismatches must refuse (return False) so the caller
    falls back to re-prefill instead of corrupting a cache."""
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    rng = np.random.default_rng(2)
    a = _engine(cfg, params, max_len=96)
    req = ServeRequest(0, rng.integers(0, 250, size=8), max_new_tokens=6)
    a.submit(req)
    a.step()
    a.step()
    [req] = a.drain_inflight()

    # different max_len -> different cache capacity -> refuse
    b = _engine(cfg, params, max_len=64)
    assert not b.admit_with_state(req)
    assert req.snapshot is not None              # kept for the fallback
    # different arch -> refuse
    cfg2 = get_arch("qwen3-1.7b").reduced(n_layers=2)
    c = _engine(cfg2, T.init_params(cfg2, KEY), max_len=96)
    assert not c.admit_with_state(req)
    # the fallback path still finishes it exactly
    d = _engine(cfg, params, max_len=96)
    d.submit(req)
    d.run()
    assert req.generated == _solo(cfg, params, req.tokens, 6)


def test_admit_with_state_respects_epoch_barrier():
    """A batch mid-epoch on a different adapter must refuse the import
    (merged-LoRA weights apply to every slot)."""
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    merged = merge_lora(params, randomize_lora(
        jax.random.fold_in(KEY, 3), init_lora(KEY, cfg, rank=4)))
    rng = np.random.default_rng(3)

    a = ServingEngine(cfg, params, n_slots=2, max_len=96,
                      adapter_params={"a": merged})
    a.batcher.sampler = quantized_greedy
    mig = ServeRequest(0, rng.integers(0, 250, size=8), max_new_tokens=6,
                       adapter="a")
    a.submit(mig)
    a.step()
    a.step()
    [mig] = a.drain_inflight()

    # survivor busy on BASE weights -> refuse the adapter-tagged import
    b = ServingEngine(cfg, params, n_slots=2, max_len=96,
                      adapter_params={"a": merged})
    b.batcher.sampler = quantized_greedy
    b.submit(ServeRequest(1, rng.integers(0, 250, size=8),
                          max_new_tokens=12))
    b.step()
    assert not b.admit_with_state(mig)
    # survivor without the adapter at all -> refuse
    c = _engine(cfg, params)
    assert not c.admit_with_state(mig)
    # idle survivor WITH the adapter -> switches and resumes exactly
    d = ServingEngine(cfg, params, n_slots=2, max_len=96,
                      adapter_params={"a": merged})
    d.batcher.sampler = quantized_greedy
    assert d.admit_with_state(mig)
    while d.batcher.n_active:
        d.step()
    assert mig.generated == _solo(cfg, merged, mig.tokens, 6)


def test_ring_zero_copy_step_matches_write_path():
    """The windowed decode step's zero-copy form (merged partial + evicted
    slot masked) must equal the legacy write-then-attend ring path."""
    from repro.models.transformer import attn_layer_step
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=1, attn_window=8)
    params = T.init_params(cfg, KEY)
    p_l = jax.tree.map(lambda a: a[0], params["blocks"]["attn"])
    B, C, hd = 3, 8, cfg.resolved_head_dim
    rng = jax.random.PRNGKey(4)
    x = jax.random.normal(rng, (B, 1, cfg.d_model), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(rng, 1),
                           (B, C, cfg.n_kv_heads, hd), jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(rng, 2),
                           (B, C, cfg.n_kv_heads, hd), jnp.float32)
    # per-slot positions: unwrapped, exactly-at-capacity, wrapped
    for pos_vals in ([3, 8, 13], [1, 7, 20]):
        pos = jnp.asarray(pos_vals, jnp.int32)
        x0, k0, v0 = attn_layer_step(cfg, p_l, x, pos[:, None], kc, vc, pos,
                                     zero_copy=False)
        x1, k1, v1 = attn_layer_step(cfg, p_l, x, pos[:, None], kc, vc, pos,
                                     zero_copy=True)
        np.testing.assert_allclose(np.asarray(x0), np.asarray(x1),
                                   atol=2e-5, rtol=2e-5)
        # write path returns the full cache; zero-copy returns the row the
        # caller scatters at pos % C — they must agree there
        slot = np.mod(pos_vals, C)
        bidx = np.arange(B)
        np.testing.assert_allclose(np.asarray(k0)[bidx, slot],
                                   np.asarray(k1), atol=1e-6)
        np.testing.assert_allclose(np.asarray(v0)[bidx, slot],
                                   np.asarray(v1), atol=1e-6)


def test_reconstruct_inflight_partial_layers():
    """Batcher-level §4.4.2: wipe some layers' state under live requests,
    rebuild only those, decode continues token-exact."""
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 250, size=L) for L in (12, 7)]
    srv = _engine(cfg, params, n_slots=2)
    reqs = [ServeRequest(i, p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    for _ in range(3):
        srv.step()
    cache = srv.batcher.cache
    for leaf in ("k", "v"):
        z = cache["attn"][leaf]
        cache["attn"][leaf] = z.at[1:3].set(jnp.zeros_like(z[1:3]))
    stats = srv.reconstruct_inflight([True, False, False, True])
    assert stats["reconstructed_reqs"] == 2
    assert stats["kv_reused"] == 2       # layer 0, per request
    assert stats["full_prefill"] == 4    # layers 1-2, per request
    assert stats["layers_skipped"] == 2  # layer 3 untouched
    assert stats["q_only_tokens"] > 0 and stats["prefill_tokens"] > 0
    while srv.batcher.n_active:
        srv.step()
    for i, p in enumerate(prompts):
        assert reqs[i].generated == _solo(cfg, params, p, 8), i


@pytest.mark.parametrize("arch,kw", [
    ("qwen3-1.7b", {}),                          # dense, full-length cache
    ("qwen3-1.7b", {"attn_window": 8}),          # pure-attn ring buffer
    ("mamba2-780m", {}),                         # SSM state only
])
def test_batched_import_matches_sequential(arch, kw):
    """A survivor absorbing several victims imports their snapshots in ONE
    donated scatter (import_snapshots) with the same continuations as N
    sequential import_snapshot calls — and one batched-import dispatch."""
    cfg = get_arch(arch).reduced(n_layers=4, **kw)
    params = T.init_params(cfg, KEY)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 250, size=L) for L in (20, 11, 15)]

    def drained_victims():
        a = _engine(cfg, params, n_slots=4)
        reqs = [ServeRequest(i, p, max_new_tokens=10)
                for i, p in enumerate(prompts)]
        for r in reqs:
            a.submit(r)
        for _ in range(4):
            a.step()
        return a.drain_inflight()

    b = _engine(cfg, params, n_slots=4)
    batch = drained_victims()
    accepted = b.admit_with_state_batch(batch)
    assert sorted(r.rid for r in accepted) == [0, 1, 2]
    assert b.batcher.n_batched_imports == 1      # ONE scatter dispatch
    assert b.batcher.n_migrated_in == 3
    assert b.batcher.n_prefill_reqs == 0         # zero re-prefill
    while b.batcher.n_active:
        b.step()

    c = _engine(cfg, params, n_slots=4)
    seq = drained_victims()
    for r in seq:
        assert c.admit_with_state(r)
    while c.batcher.n_active:
        c.step()
    for x, y in zip(sorted(accepted, key=lambda r: r.rid),
                    sorted(seq, key=lambda r: r.rid)):
        assert x.generated == y.generated, (x.rid, x.generated, y.generated)
        assert x.generated == _solo(cfg, params, prompts[x.rid], 10)


def test_batched_import_partial_capacity():
    """With fewer free slots than victims, import_snapshots takes what
    fits and hands the rest back for the re-prefill fallback."""
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    params = T.init_params(cfg, KEY)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 250, size=10 + i) for i in range(3)]
    a = _engine(cfg, params, n_slots=4)
    reqs = [ServeRequest(i, p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        a.submit(r)
    for _ in range(3):
        a.step()
    drained = a.drain_inflight()

    b = _engine(cfg, params, n_slots=3)
    resident = ServeRequest(9, rng.integers(0, 250, size=8),
                            max_new_tokens=12)
    b.submit(resident)
    b.step()                                     # 2 free slots remain
    accepted = b.admit_with_state_batch(drained)
    assert len(accepted) == 2
    left = [r for r in drained if r.rid not in {x.rid for x in accepted}]
    assert len(left) == 1 and left[0].snapshot is not None
    while b.batcher.n_active:
        b.step()
    for r in accepted:
        assert r.generated == _solo(cfg, params, prompts[r.rid], 8)
    assert resident.generated == _solo(cfg, params, resident.tokens, 12)


def test_crash_of_migration_target_mid_import():
    """Overlapping faults: the server that absorbed a migrated request
    crashes too, mid-decode.  The snapshot chain (A -> B -> C) survives a
    second hop and the final tokens still equal the uninterrupted run —
    snapshots compose."""
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, 250, size=15)

    a = _engine(cfg, params)
    req = ServeRequest(0, prompt, max_new_tokens=12)
    a.submit(req)
    for _ in range(4):
        a.step()
    [req] = a.drain_inflight()               # first crash: A dies
    pos_a = req.snapshot.pos

    b = _engine(cfg, params)
    assert b.admit_with_state(req)
    for _ in range(3):
        b.step()                             # the import decodes a while
    assert not req.done
    [req] = b.drain_inflight()               # second crash: the TARGET dies
    assert req.snapshot is not None
    assert req.snapshot.pos > pos_a          # B's progress rode along

    c = _engine(cfg, params)
    assert c.admit_with_state(req)
    assert c.batcher.n_prefill_reqs == 0     # still zero re-prefill
    while c.batcher.n_active:
        c.step()
    assert req.done
    assert req.generated == _solo(cfg, params, prompt, 12)
